"""Persistent BLCO store: format roundtrip, corruption detection,
disk-streamed execution, registry spill tier + LRU, restart stability."""
import os
import weakref

import numpy as np
import pytest

from repro import core
from repro.core.streaming import LaunchChunks
from repro.engine import factor_bytes, in_memory_bytes, plan_for
from repro.engine.plans import InMemoryPlan, StreamedPlan
from repro.service import BuildParams, TensorRegistry
from repro.store import (DiskStreamedPlan, StoreCorruptionError,
                         StoreFormatError, open_blco, save_blco)


def _factors(dims, rank=6, seed=0, dtype=np.float32):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)).astype(dtype))
            for d in dims]


def _rel_err(a, oracle):
    return np.max(np.abs(np.asarray(a, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)


# ------------------------------------------------------------------ format
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_roundtrip_exact(tmp_path, dtype):
    """save -> open -> to_blco reproduces the split u64 hi/lo indices,
    values, blocks, and launches exactly — for f32 and f64 values."""
    t = core.random_tensor((25, 18, 21), 1500, seed=4, dtype=dtype)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    nbytes = save_blco(b, path, fingerprint="fp", norm_x=2.5)
    assert nbytes == os.path.getsize(path)
    s = open_blco(path, verify=True)
    assert s.fingerprint == "fp" and s.norm_x == 2.5
    assert s.dims == b.dims and s.nnz == b.nnz
    assert s.re == b.re
    b2 = s.to_blco()
    np.testing.assert_array_equal(b2.idx_hi, b.idx_hi)
    np.testing.assert_array_equal(b2.idx_lo, b.idx_lo)
    np.testing.assert_array_equal(b2.values, b.values)
    assert b2.values.dtype == np.dtype(dtype)
    assert b2.blocks == b.blocks and b2.launches == b.launches
    assert b2.re == b.re and b2.spec == b.spec
    s.close()


def test_roundtrip_wide_index_uses_hi_word(tmp_path):
    """A >32-bit stored index exercises the hi uint32 word on disk."""
    t = core.random_tensor((1 << 13, 1 << 13, 1 << 13), 400, seed=7)
    b = core.build_blco(t)         # 39 index bits -> hi word nonzero
    assert int(b.idx_hi.max()) > 0
    path = str(tmp_path / "wide.blco")
    save_blco(b, path)
    b2 = open_blco(path, verify=True).to_blco()
    np.testing.assert_array_equal(b2.idx_hi, b.idx_hi)
    np.testing.assert_array_equal(b2.idx_lo, b.idx_lo)


def test_ragged_reservation_roundtrip(tmp_path):
    """An explicit non-pow2 reservation is honoured on disk and on read."""
    t = core.random_tensor((20, 16, 12), 3000, seed=1)
    b = core.build_blco(t, max_nnz_per_block=128)
    max_launch = max(l.nnz for l in b.launches)
    res = max_launch + 3                    # deliberately ragged
    path = str(tmp_path / "ragged.blco")
    save_blco(b, path, reservation_nnz=res)
    s = open_blco(path, verify=True)
    assert s.reservation_nnz == res
    hi, lo, vals, bases, n = s.chunk(0)
    assert hi.shape == (res,) and bases.shape == (res, t.order)
    factors = _factors(t.dims)
    plan = DiskStreamedPlan(s, queues=2)
    oracle = core.mttkrp_dense_oracle(t, factors, 1)
    assert _rel_err(plan.mttkrp(factors, 1), oracle) < 1e-3
    plan.close()


def test_open_rejects_non_store_and_bad_version(tmp_path):
    path = str(tmp_path / "junk.blco")
    with open(path, "wb") as f:
        f.write(b"NOTASTORE" + b"\0" * 64)
    with pytest.raises(StoreFormatError, match="not a BLCO store"):
        open_blco(path)
    # valid file, wrong version
    t = core.random_tensor((8, 7, 6), 50, seed=0)
    good = str(tmp_path / "good.blco")
    save_blco(core.build_blco(t), good)
    raw = bytearray(open(good, "rb").read())
    raw[8:12] = (99).to_bytes(4, "little")
    bad = str(tmp_path / "badver.blco")
    open(bad, "wb").write(bytes(raw))
    with pytest.raises(StoreFormatError, match="version 99"):
        open_blco(bad)


def test_truncated_file_detected_without_verify(tmp_path):
    t = core.random_tensor((20, 16, 12), 800, seed=2)
    path = str(tmp_path / "t.blco")
    save_blco(core.build_blco(t, max_nnz_per_block=128), path)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 1)
    with pytest.raises(StoreCorruptionError, match="past end of file"):
        open_blco(path)        # bounds check runs even with verify=False


def test_corrupted_section_detected_by_checksum(tmp_path):
    t = core.random_tensor((20, 16, 12), 800, seed=3)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    s = open_blco(path)                     # find a real data byte to flip
    sec = s._header["sections"]["vals"]
    s.close()
    with open(path, "r+b") as f:
        f.seek(sec["offset"] + 5)
        byte = f.read(1)
        f.seek(sec["offset"] + 5)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(StoreCorruptionError, match="checksum mismatch"):
        open_blco(path, verify=True)
    # header corruption is caught even without verify
    with open(path, "r+b") as f:
        f.seek(25)
        f.write(b"\xff")
    with pytest.raises(StoreCorruptionError):
        open_blco(path)


def test_empty_tensor_roundtrip(tmp_path):
    t = core.from_coo(np.zeros((0, 3), np.int64), np.zeros((0,), np.float32),
                      (8, 6, 4))
    b = core.build_blco(t)
    path = str(tmp_path / "empty.blco")
    save_blco(b, path)
    s = open_blco(path, verify=True)
    assert s.num_launches == 0 and s.to_blco().nnz == 0
    plan = DiskStreamedPlan(s)
    out = np.asarray(plan.mttkrp(_factors(t.dims, 5), 0))
    assert out.shape == (8, 5)
    np.testing.assert_array_equal(out, 0.0)
    plan.close()


# -------------------------------------------------------- disk-streamed plan
def test_disk_streamed_matches_in_memory_bitwise(tmp_path):
    """Acceptance: DiskStreamedPlan output == InMemoryPlan output
    bit-for-bit, on every mode and both conflict resolutions."""
    t = core.random_tensor((40, 25, 30), 2500, seed=5)
    b = core.build_blco(t, max_nnz_per_block=256)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    disk = DiskStreamedPlan(path, queues=3)
    mem = plan_for(b, 1 << 40, rank=6, backend="in_memory")
    factors = _factors(t.dims)
    for mode in range(t.order):
        for res in ("register", "direct"):
            np.testing.assert_array_equal(
                np.asarray(disk.mttkrp(factors, mode, resolution=res)),
                np.asarray(mem.mttkrp(factors, mode, resolution=res)),
                err_msg=f"mode {mode} res {res}")
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        assert _rel_err(disk.mttkrp(factors, mode), oracle) < 1e-3
    s = disk.stats()
    assert s.backend == "disk_streamed"
    assert s.disk_bytes == s.h2d_bytes > 0 and s.launches > 0
    freed = disk.close()
    assert freed == disk.spec.bytes_in_flight(3)
    assert disk.device_bytes() == 0
    mem.close()


def test_disk_streamed_holds_bounded_host_window(tmp_path):
    """Acceptance: at most ``queues`` reservation chunks of padded host
    memory are alive at any point while disk-streaming (tracked via
    weakref finalizers on every chunk the plan pulls)."""
    t = core.random_tensor((30, 22, 26), 4000, seed=6)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    stored = open_blco(path)
    queues = 3
    plan = DiskStreamedPlan(stored, queues=queues)
    assert plan.host_window_bytes() == queues * plan.spec.bytes_per_launch

    live = {"now": 0, "peak": 0, "total": 0}
    real_chunk = stored.chunk

    def tracking_chunk(i):
        out = real_chunk(i)
        arr = np.array(out[0])          # a per-chunk allocation we can track
        live["now"] += 1
        live["total"] += 1
        live["peak"] = max(live["peak"], live["now"])

        def _dead(_ref=None):
            live["now"] -= 1
        weakref.finalize(arr, _dead)
        return (arr,) + out[1:]

    stored.chunk = tracking_chunk
    plan.mttkrp(_factors(t.dims), 0)
    n_launches = len(b.launches)
    assert n_launches > 2 * queues       # the test only means something then
    assert live["total"] == n_launches
    # the streaming loop keeps <= queues transfers in flight; allow the one
    # chunk being issued on top of the full window
    assert live["peak"] <= queues + 1, live
    plan.close()


def test_plan_for_disk_regime_and_host_budget(tmp_path):
    """plan_for picks the disk tier when the tensor exceeds the host
    budget, honours backend="disk_streamed", and cleans up temp spills."""
    t = core.random_tensor((30, 22, 26), 2000, seed=8)
    b = core.build_blco(t, max_nnz_per_block=256)
    factors = _factors(t.dims)

    # auto: host budget below the tensor's host footprint -> disk tier
    plan = plan_for(b, 1 << 40, rank=6,
                    host_budget_bytes=core.format_bytes(b) - 1)
    assert isinstance(plan, DiskStreamedPlan)
    temp_file = plan.stored.path
    assert os.path.exists(temp_file)
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    assert _rel_err(plan.mttkrp(factors, 0), oracle) < 1e-3
    plan.close()
    assert not os.path.exists(temp_file)    # anonymous spill is cleaned up

    # auto with a generous host budget stays in memory
    assert isinstance(plan_for(b, 1 << 40, rank=6,
                               host_budget_bytes=1 << 40), InMemoryPlan)

    # explicit backend + explicit store path -> file is kept
    keep = str(tmp_path / "kept.blco")
    plan = plan_for(b, 1 << 40, rank=6, backend="disk_streamed",
                    store_path=keep)
    plan.mttkrp(factors, 1)
    plan.close()
    assert os.path.exists(keep)

    # device budget still binds: reservation + factors must fit
    with pytest.raises(ValueError, match="disk-streamed plan needs"):
        plan_for(b, 1, rank=6, backend="disk_streamed")


# ------------------------------------------------- lazy host streaming window
def test_streamed_plan_pads_lazily_bounded_window():
    """Regression (eager host blow-up): StreamedPlan must not materialize
    every padded launch at construction — padding happens per chunk inside
    the streaming loop, and at most queues+1 padded chunks are alive."""
    t = core.random_tensor((30, 22, 26), 4000, seed=9)
    b = core.build_blco(t, max_nnz_per_block=128)
    queues = 3
    plan = StreamedPlan(b, queues=queues)
    chunks = plan._chunks
    assert isinstance(chunks, LaunchChunks)
    assert chunks.pads == 0                 # nothing padded at construction
    assert plan.host_window_bytes() == queues * plan.spec.bytes_per_launch

    live = {"now": 0, "peak": 0}
    real_chunk = chunks.chunk

    def tracking_chunk(i):
        out = real_chunk(i)
        live["now"] += 1
        live["peak"] = max(live["peak"], live["now"])

        def _dead(_ref=None):
            live["now"] -= 1
        weakref.finalize(out[0], _dead)
        return out

    chunks.chunk = tracking_chunk
    plan.mttkrp(_factors(t.dims), 0)
    n_launches = len(b.launches)
    assert n_launches > 2 * queues
    assert chunks.pads == n_launches        # one pass pads each launch once
    assert live["peak"] <= queues + 1, live
    plan.mttkrp(_factors(t.dims), 1)        # re-iterable across calls
    assert chunks.pads == 2 * n_launches
    plan.close()


def test_oom_executor_pads_lazily():
    t = core.random_tensor((25, 18, 21), 1200, seed=4)
    b = core.build_blco(t, max_nnz_per_block=128)
    ex = core.OOMExecutor(b, queues=2)
    assert isinstance(ex._prepared, LaunchChunks)
    assert ex._prepared.pads == 0
    ex.mttkrp(_factors(t.dims, 4), 0)
    assert ex._prepared.pads == len(b.launches)


# ------------------------------------------------------- registry spill tier
def _registry_tensor(seed=0, nnz=900):
    return core.random_tensor((30, 22, 26), nnz, seed=seed)


def test_registry_spill_load_roundtrip(tmp_path):
    reg = TensorRegistry(store_dir=str(tmp_path))
    build = BuildParams(max_nnz_per_block=256)
    t = _registry_tensor()
    h = reg.register(t, build=build)
    hb = reg.host_bytes()
    assert hb == h.host_bytes > 0
    blco_before = h.blco

    freed = reg.spill(h.key)
    assert freed == hb and not h.resident and h.chunks is None
    assert reg.host_bytes() == 0 and reg.store_bytes() > 0
    assert reg.spill(h.key) == 0            # idempotent

    reg.load(h.key)
    assert h.resident and reg.host_bytes() == hb and reg.loads == 1
    np.testing.assert_array_equal(h.blco.idx_hi, blco_before.idx_hi)
    np.testing.assert_array_equal(h.blco.values, blco_before.values)
    assert h.blco.launches == blco_before.launches


def test_registry_spill_refuses_pinned(tmp_path):
    reg = TensorRegistry(store_dir=str(tmp_path))
    h = reg.register(_registry_tensor(), build=BuildParams(max_nnz_per_block=256))
    h.pin()
    with pytest.raises(RuntimeError, match="pinned"):
        reg.spill(h.key)
    h.unpin()
    assert reg.spill(h.key) > 0


def test_registry_lru_spills_over_host_budget(tmp_path):
    """Satellite: automatic LRU eviction over host_bytes() — least
    recently used unpinned handle spills to the store, pinned handles
    survive even over budget."""
    build = BuildParams(max_nnz_per_block=256)
    tensors = [_registry_tensor(seed=i, nnz=900) for i in range(3)]
    probe = TensorRegistry()
    sizes = [probe.register(t, build=build).host_bytes for t in tensors]
    budget = sizes[0] + sizes[1] + sizes[2] // 2     # fits two, not three

    reg = TensorRegistry(store_dir=str(tmp_path), host_budget_bytes=budget)
    h0 = reg.register(tensors[0], build=build)
    h1 = reg.register(tensors[1], build=build)
    assert reg.host_bytes() <= budget and reg.spills == 0
    reg.get(h0.key)                          # h1 becomes least recently used
    h2 = reg.register(tensors[2], build=build)
    assert reg.spills == 1
    assert not h1.resident and h0.resident and h2.resident   # LRU spilled h1
    assert reg.host_bytes() <= budget

    # spilled entries stay registered: a re-register is a (disk) hit
    misses = reg.misses
    assert reg.register(tensors[1], build=build) is h1
    assert reg.misses == misses

    # pinned handles are never spilled, even over budget
    h0.pin(); h2.pin()
    reg.load(h1.key)                         # load pushes us over budget
    assert reg.host_bytes() > budget or not h1.resident
    assert h0.resident and h2.resident
    h0.unpin(); h2.unpin()


def test_registry_restart_reuses_fingerprint_no_rebuild(tmp_path):
    """Acceptance: a spilled-then-reloaded entry reuses its fingerprint
    (no BLCO rebuild) across a simulated process restart."""
    build = BuildParams(max_nnz_per_block=256)
    t = _registry_tensor(seed=3)
    reg1 = TensorRegistry(store_dir=str(tmp_path))
    h1 = reg1.register(t, build=build)
    reg1.spill(h1.key)
    assert reg1.misses == 1

    # "restart": a brand-new registry over the same store directory
    reg2 = TensorRegistry(store_dir=str(tmp_path))
    h2 = reg2.register(t, build=build)
    assert h2.key == h1.key
    assert reg2.misses == 0 and reg2.disk_hits == 1 and reg2.hits == 1
    assert not h2.resident and h2.store_path == h1.store_path
    assert h2.dims == t.dims and h2.nnz == t.nnz
    assert h2.norm_x == pytest.approx(h1.norm_x)
    # the reloaded BLCO is bit-identical to the original build
    reg2.load(h2.key)
    reg1.load(h1.key)
    np.testing.assert_array_equal(h2.blco.idx_hi, h1.blco.idx_hi)
    np.testing.assert_array_equal(h2.blco.idx_lo, h1.blco.idx_lo)
    np.testing.assert_array_equal(h2.blco.values, h1.blco.values)
    assert h2.blco.launches == h1.blco.launches


def test_registry_load_is_not_immediately_respilled(tmp_path):
    """Regression: load() of a tensor bigger than the whole host budget
    must return a RESIDENT handle (and count one load, not a spill/load
    churn) — an explicit reload is exempt from its own eviction pass."""
    build = BuildParams(max_nnz_per_block=256)
    t = _registry_tensor(seed=5)
    probe = TensorRegistry()
    size = probe.register(t, build=build).host_bytes

    reg = TensorRegistry(store_dir=str(tmp_path), host_budget_bytes=size // 2)
    h = reg.register(t, build=build)
    assert not h.resident and reg.spills == 1    # auto-spilled over budget
    reg.load(h.key)
    assert h.resident                            # NOT spilled straight back
    assert reg.loads == 1 and reg.spills == 1    # no churn, no double count
    assert reg.host_bytes() > reg.host_budget_bytes   # over budget, like pins
    # but a later registration still evicts it normally (it is plain LRU)
    reg.register(_registry_tensor(seed=6), build=build)
    assert not h.resident and reg.spills >= 2


def test_register_falls_back_to_rebuild_on_corrupt_store_file(tmp_path):
    """Regression: a damaged <fingerprint>.blco (crash mid-write, bit rot)
    must not brick registration while the COO is in hand — register()
    falls back to a rebuild, and the next spill repairs the disk tier."""
    build = BuildParams(max_nnz_per_block=256)
    t = _registry_tensor(seed=7)
    reg1 = TensorRegistry(store_dir=str(tmp_path))
    h1 = reg1.register(t, build=build)
    reg1.spill(h1.key)
    with open(h1.store_path, "r+b") as f:       # damage the store file
        f.truncate(os.path.getsize(h1.store_path) // 2)

    reg2 = TensorRegistry(store_dir=str(tmp_path))
    h2 = reg2.register(t, build=build)          # must not raise
    assert h2.resident and reg2.misses == 1 and reg2.disk_hits == 0
    assert reg2.spill(h2.key) > 0               # re-persist over the damage
    reg3 = TensorRegistry(store_dir=str(tmp_path))
    assert not reg3.register(t, build=build).resident
    assert reg3.disk_hits == 1                  # disk tier repaired

    # data-only corruption (valid header, bad section bytes) must ALSO be
    # caught at adoption — silently streaming bit-rotted values would be
    # worse than the rebuild
    path = reg3.get(h1.key).store_path
    s = open_blco(path)
    off = s._header["sections"]["vals"]["offset"]
    s.close()
    with open(path, "r+b") as f:
        f.seek(off + 3)
        byte = f.read(1)
        f.seek(off + 3)
        f.write(bytes([byte[0] ^ 0xFF]))
    reg4 = TensorRegistry(store_dir=str(tmp_path))
    h4 = reg4.register(t, build=build)          # rebuild, not garbage
    assert h4.resident and reg4.misses == 1 and reg4.disk_hits == 0


def test_save_blco_is_atomic(tmp_path, monkeypatch):
    """save_blco commits via rename: no .tmp remnants on success, and a
    mid-write failure leaves nothing at the final path."""
    import repro.store.format as fmt
    t = core.random_tensor((20, 16, 12), 800, seed=2)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    assert os.path.exists(path) and not os.path.exists(path + ".tmp")

    # fail partway through the data pass: neither the final path nor the
    # temp file may survive (a crashed persist must not brick adoption)
    class Boom(fmt.LaunchChunks):
        def chunk(self, i):
            if i >= 2:
                raise RuntimeError("simulated crash mid-write")
            return super().chunk(i)

    monkeypatch.setattr(fmt, "LaunchChunks", Boom)
    bad = str(tmp_path / "bad.blco")
    with pytest.raises(RuntimeError, match="mid-write"):
        save_blco(b, bad)
    assert not os.path.exists(bad) and not os.path.exists(bad + ".tmp")


def test_service_reloads_spilled_tensor_when_host_has_room(tmp_path):
    """Submit-path tier policy: an adopted/spilled tensor is reloaded to
    the host (regaining the in-memory fast path) when the host budget has
    room, and disk-streams only under genuine host pressure."""
    from repro.service import DecompositionService, SubmitDecomposition
    build = BuildParams(max_nnz_per_block=256)
    t = _registry_tensor()
    seed_reg = TensorRegistry(store_dir=str(tmp_path))
    h = seed_reg.register(t, build=build)
    size = h.host_bytes
    seed_reg.spill(h.key)                     # the store file a restart sees

    roomy = DecompositionService(device_budget_bytes=64 << 20,
                                 store_dir=str(tmp_path))
    jid = roomy.submit(SubmitDecomposition(tensor=t, rank=4, iters=1,
                                           tol=0.0, build=build))
    assert roomy.status(jid).backend == "in_memory"   # reloaded off disk
    assert roomy.registry.misses == 0                 # ... without a rebuild

    pressed = DecompositionService(device_budget_bytes=64 << 20,
                                   store_dir=str(tmp_path),
                                   host_budget_bytes=size // 2)
    jid2 = pressed.submit(SubmitDecomposition(tensor=t, rank=4, iters=1,
                                              tol=0.0, build=build))
    assert pressed.status(jid2).backend == "disk_streamed"  # stub stays
    roomy.run(); pressed.run()
    assert roomy.status(jid).state == pressed.status(jid2).state == "done"


def test_registry_without_store_dir_cannot_spill():
    reg = TensorRegistry()
    h = reg.register(_registry_tensor(), build=BuildParams(max_nnz_per_block=256))
    with pytest.raises(RuntimeError, match="store_dir"):
        reg.spill(h.key)
