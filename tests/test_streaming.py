"""OOMExecutor edge cases: zero-nnz, exact-fit reservations, byte accounting."""
import numpy as np
import pytest

from repro import core
from repro.core.streaming import ReservationSpec, prepare_chunks


def _factors(dims, rank, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)).astype(np.float32))
            for d in dims]


def test_zero_nnz_tensor():
    t = core.from_coo(np.zeros((0, 3), np.int64), np.zeros((0,), np.float32),
                      (8, 6, 4))
    assert t.nnz == 0
    b = core.build_blco(t)
    assert b.launches == [] and b.blocks == []
    ex = core.OOMExecutor(b, queues=2)
    out = np.asarray(ex.mttkrp(_factors(t.dims, 5), 0))
    assert out.shape == (8, 5)
    np.testing.assert_array_equal(out, 0.0)
    assert ex.stats.launches == 0 and ex.stats.h2d_bytes == 0


def test_launch_exactly_at_reservation_size():
    t = core.random_tensor((20, 16, 12), 3000, seed=1)
    b = core.build_blco(t, max_nnz_per_block=128)
    max_launch = max(l.nnz for l in b.launches)
    ex = core.OOMExecutor(b, queues=2, reservation_nnz=max_launch)
    assert ex.reservation == max_launch           # no pow2 rounding up
    out = np.asarray(ex.mttkrp(_factors(t.dims, 6), 1), np.float64)
    oracle = core.mttkrp_dense_oracle(t, _factors(t.dims, 6), 1)
    rel = np.max(np.abs(out - oracle)) / (np.max(np.abs(oracle)) + 1e-30)
    assert rel < 1e-3
    # a reservation below the largest launch must be rejected up front
    with pytest.raises(ValueError, match="reservation smaller"):
        core.OOMExecutor(b, queues=2, reservation_nnz=max_launch - 1)
    with pytest.raises(ValueError, match="exceeds reservation"):
        prepare_chunks(b, max_launch - 1)


def test_stream_stats_byte_accounting():
    t = core.random_tensor((25, 18, 21), 1200, seed=4)
    b = core.build_blco(t, max_nnz_per_block=128)
    ex = core.OOMExecutor(b, queues=3)
    factors = _factors(t.dims, 4)
    ex.mttkrp(factors, 0)
    n_launches = len(b.launches)
    assert ex.stats.launches == n_launches
    # every launch moves exactly one reservation: hi + lo + vals + bases
    per_launch = ex.spec.bytes_per_launch
    assert per_launch == ex.reservation * (4 + 4 + 4 + 4 * t.order)
    assert ex.stats.h2d_bytes == n_launches * per_launch
    # stats accumulate across calls (per-executor lifetime accounting)
    ex.mttkrp(factors, 2)
    assert ex.stats.launches == 2 * n_launches
    assert ex.stats.h2d_bytes == 2 * n_launches * per_launch
    assert ex.stats.total_time_s > 0 and ex.stats.compute_time_s > 0


def test_reservation_spec_bytes():
    spec = ReservationSpec(nnz=256, order=4, value_itemsize=4)
    assert spec.bytes_per_launch == 256 * (4 + 4 + 4 + 16)
    assert spec.bytes_in_flight(4) == 4 * spec.bytes_per_launch


def test_format_bytes_agrees_with_reservation_accounting():
    """Regression: ``format_bytes`` and ``ReservationSpec.bytes_per_launch``
    must agree on the true per-element device footprint (hi + lo + vals +
    bases).  Historically format_bytes omitted the bases arrays, so the
    in-memory and streaming regimes disagreed about the same tensor."""
    t = core.random_tensor((20, 16, 12, 9), 900, seed=2)
    b = core.build_blco(t)
    per_elem = 4 + 4 + b.values.dtype.itemsize + 4 * b.order
    assert core.format_bytes(b) == b.nnz * per_elem
    # a reservation sized exactly to the tensor holds exactly format_bytes
    spec = ReservationSpec(nnz=b.nnz, order=b.order,
                           value_itemsize=b.values.dtype.itemsize)
    assert spec.bytes_per_launch == core.format_bytes(b)
    # and the device-resident copy reports the same accounting (padded)
    from repro.core.mttkrp import DeviceBLCO
    dev = DeviceBLCO(b)
    padded = -(-b.nnz // 256) * 256
    assert dev.device_bytes() == padded * per_elem
    dev.delete()


def test_engine_stats_fields_and_alias():
    """StreamStats is the unified EngineStats; compute_time_s reads the
    fenced device span, not the async dispatch span."""
    assert core.StreamStats is core.EngineStats
    t = core.random_tensor((25, 18, 21), 1200, seed=4)
    b = core.build_blco(t, max_nnz_per_block=128)
    ex = core.OOMExecutor(b, queues=3)
    ex.mttkrp(_factors(t.dims, 4), 0)
    s = ex.stats
    assert s.backend == "streamed" and s.mttkrp_calls == 1
    assert s.device_time_s >= s.dispatch_time_s > 0
    assert s.compute_time_s == s.device_time_s
    assert set(s.snapshot()) >= {"h2d_bytes", "launches", "put_time_s",
                                 "dispatch_time_s", "device_time_s",
                                 "total_time_s"}
