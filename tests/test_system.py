"""End-to-end behaviour of the paper's system.

The paper's headline claims, checked at CPU scale:
 1. one BLCO copy + one implementation serves every mode (mode-agnostic);
 2. conflict resolution produces exact results under heavy duplication
    (dense fibers);
 3. out-of-memory streaming produces identical results to in-memory;
 4. CP-ALS over BLCO decomposes a real low-rank signal;
 5. the technique integrates into the LM substrate (embedding-grad path).
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.kernels import pallas_mttkrp


def test_unified_implementation_all_modes_one_copy():
    # chicago-like: large nnz but dense-materializable for the oracle
    # (uber-like's dense form is 69 GB — oracle only works on small dims)
    t = core.paper_like("chicago-like", seed=0)
    b = core.build_blco(t)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 32)).astype(np.float32)
               for d in t.dims]
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        out = np.asarray(core.mttkrp(b, factors, mode), np.float64)
        rel = np.max(np.abs(out - oracle)) / (np.max(np.abs(oracle)) + 1e-30)
        assert rel < 1e-3, (mode, rel)


def test_heavy_conflicts_exact():
    """All nnz share one target index -> worst-case conflict chain."""
    rng = np.random.default_rng(1)
    n = 4096
    idx = np.stack([np.zeros(n, np.int64),
                    rng.integers(0, 64, n),
                    rng.integers(0, 64, n)], 1)
    t = core.from_coo(idx, rng.standard_normal(n).astype(np.float32),
                      (4, 64, 64))
    b = core.build_blco(t)
    factors = [rng.standard_normal((d, 16)).astype(np.float32) for d in t.dims]
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    for res in ("register", "hierarchical"):
        out = np.asarray(core.mttkrp(b, factors, 0, resolution=res), np.float64)
        np.testing.assert_allclose(out, oracle, rtol=2e-3, atol=2e-3)
    out = np.asarray(pallas_mttkrp(b, factors, 0), np.float64)
    np.testing.assert_allclose(out, oracle, rtol=2e-3, atol=2e-3)


def test_oom_streaming_equals_in_memory():
    t = core.paper_like("vast-like", seed=2)
    # small reservation -> forced multi-launch streaming
    b = core.build_blco(t, max_nnz_per_block=4096)
    ex = core.OOMExecutor(b, queues=4)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 16)).astype(np.float32)
               for d in t.dims]
    for mode in range(t.order):
        a = np.asarray(ex.mttkrp(factors, mode))
        c = np.asarray(core.mttkrp(b, factors, mode))
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)
    assert ex.stats.launches >= len(b.launches)
    assert ex.stats.h2d_bytes > 0


def test_cpals_on_planted_low_rank():
    rng = np.random.default_rng(3)
    dims, r0 = (30, 25, 20), 4
    f0 = [np.abs(rng.standard_normal((d, r0))) + 0.1 for d in dims]
    dense = np.einsum("ir,jr,kr->ijk", *f0)
    # ALL entries kept: CP-ALS fits the tensor itself (unobserved entries
    # would make this a completion problem, which ALS-on-zeros cannot solve)
    keep = np.abs(dense) > 1e-9
    idx = np.argwhere(keep)
    t = core.from_coo(idx, dense[keep].astype(np.float32), dims)
    b = core.build_blco(t)
    res = core.cp_als(lambda f, m: core.mttkrp(b, f, m), dims, 8,
                      norm_x=float(np.linalg.norm(t.values)), iters=40,
                      seed=4, tol=1e-8)
    assert res.fits[-1] > 0.95, res.fits[-3:]


def test_technique_in_lm_substrate():
    """embed_grad=segment trains identically to scatter (same losses)."""
    import dataclasses
    from repro.configs import get_config
    from repro.launch import steps
    from repro.models import build_model
    from repro.optim import adamw

    losses = {}
    for method in ("segment", "scatter"):
        cfg = dataclasses.replace(get_config("minicpm_2b").reduced(),
                                  embed_grad=method, compute_dtype="float32")
        model = build_model(cfg)
        opt_cfg = adamw.AdamWConfig(total_steps=10, peak_lr=1e-3)
        step = jax.jit(steps.make_train_step(cfg, opt_cfg))
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        rng = np.random.default_rng(0)
        ls = []
        for i in range(4):
            batch = {"tokens": jnp.asarray(
                         (rng.zipf(1.3, (2, 32)) % cfg.vocab_size).astype(np.int32)),
                     "labels": jnp.asarray(
                         rng.integers(0, cfg.vocab_size, (2, 32)))}
            state, m = step(state, batch)
            ls.append(float(m["loss"]))
        losses[method] = ls
    np.testing.assert_allclose(losses["segment"], losses["scatter"],
                               rtol=1e-4, atol=1e-4)
