"""Trace-tier verifier suite (ISSUE 9).

Each verifier family gets at least one known-bad fixture it must reject
(a hand-broken layout, an unbounded rounding, a callback/narrowing jaxpr,
an unsound ``unique_indices`` claim) plus a clean fixture it must accept,
alongside the integration checks: the committed tree verifies clean, the
write-conflict prover's per-launch report feeds the segmented-reduction
invariant test, and the encoding verifier round-trips against the host
and device delinearizers — property-tested under hypothesis where
available, with the adversarial corners pinned deterministically so the
coverage survives the stub.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

from repro import core
from repro.analysis.trace import (DEFAULT_CONFIGS, PASS_CALLBACK,
                                  PASS_CHURN, PASS_CONFLICT, PASS_ENCODING,
                                  PASS_NARROWING, TRACE_PASS_IDS,
                                  audit_callbacks, audit_hot_path,
                                  audit_narrowing, audit_reservation_churn,
                                  audit_tenant_invariance,
                                  check_scatter_claims,
                                  check_write_structure, conflict_report,
                                  prove_encoding, prove_variant,
                                  registered_hot_paths, run_trace_tier,
                                  scatter_facts, trace_jaxpr, verify_layout)
from repro.analysis.trace.cachekeys import audit_rounding, churn_bound
from repro.core import linearize as lin
from repro.core import u64
from repro.core.launches import LaunchCache
from repro.core.padding import LANE, pad_multiple
from repro.kernels.fused import fused_cache_mttkrp
from repro.kernels.ref import delinearize_ref

given, settings, st = hypothesis_or_stub()

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ------------------------------------------------------------ jaxpr audits
def test_callback_fixture_rejected():
    """Known-bad: a pure_callback staged inside a jitted region."""
    def bad(x):
        y = jax.pure_callback(lambda a: a,
                              jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    closed = trace_jaxpr(jax.jit(bad), _f32(8))
    fs = audit_callbacks(closed, path="tests/fixture.py", symbol="bad")
    assert fs and all(f.pass_id == PASS_CALLBACK for f in fs)
    assert "host round-trip" in fs[0].message
    # the walk found it inside the pjit sub-jaxpr, not at top level
    assert "pjit" in fs[0].message


def test_callback_audit_clean_on_plain_math():
    closed = trace_jaxpr(jax.jit(lambda x: (x * 2.0).sum()), _f32(8))
    assert audit_callbacks(closed, path="p", symbol="s") == []


def test_narrowing_fixture_rejected():
    """Known-bad: f32 values squeezed to bf16 ahead of a scatter-add."""
    def bad(vals, idx):
        small = vals.astype(jnp.bfloat16)
        out = jnp.zeros((16,), jnp.bfloat16)
        return out.at[idx].add(small)

    closed = trace_jaxpr(bad, _f32(32), _i32(32))
    fs = audit_narrowing(closed, path="tests/fixture.py", symbol="bad")
    assert fs and all(f.pass_id == PASS_NARROWING for f in fs)
    assert "scatter-add" in fs[0].message


def test_narrowing_taint_survives_rewidening():
    """Re-widening after the lossy convert must NOT clear the finding."""
    def bad(vals, idx):
        laundered = vals.astype(jnp.bfloat16).astype(jnp.float32)
        out = jnp.zeros((16,), jnp.float32)
        return out.at[idx].add(laundered)

    closed = trace_jaxpr(bad, _f32(32), _i32(32))
    assert audit_narrowing(closed, path="p", symbol="s")


def test_narrowing_ignores_integer_converts():
    """Index math between integer widths is not precision loss."""
    def fine(vals, idx):
        out = jnp.zeros((16,), jnp.float32)
        return out.at[idx.astype(jnp.int16).astype(jnp.int32)].add(vals)

    closed = trace_jaxpr(fine, _f32(32), _i32(32))
    assert audit_narrowing(closed, path="p", symbol="s") == []


def test_registered_hot_paths_audit_clean():
    """The six shipped hot paths carry no callbacks and no narrowing."""
    paths = registered_hot_paths()
    assert len(paths) == 6
    for hp in paths:
        assert audit_hot_path(hp) == [], hp.name


# -------------------------------------------------------- cache-key churn
def test_pad_multiple_reservation_is_the_known_bad_rounding():
    """Raw LANE rounding yields one executable per LANE step: unbounded."""
    fs = audit_rounding("raw_lane", pad_multiple)
    assert fs and fs[0].pass_id == PASS_CHURN
    assert "distinct reservations" in fs[0].message


def test_shipped_roundings_bounded_and_tenant_invariant():
    assert audit_reservation_churn() == []
    assert audit_tenant_invariance() == []
    assert audit_tenant_invariance(n_tenants=5000) == []


def test_unsound_roundings_rejected():
    # under-covering: reservation smaller than the launch overflows
    fs = audit_rounding("undersized", lambda n: max(LANE, n - 1))
    assert fs and "smaller than launch nnz" in fs[0].message
    # non-monotone: a bigger launch must never shrink its reservation
    fs = audit_rounding("sawtooth",
                        lambda n: 2 * LANE if n % 2 else 4 * LANE)
    assert fs and "not monotone" in fs[0].message


def test_churn_bound_is_logarithmic_in_range():
    assert churn_bound(1 << 18) == 16 * 19
    assert churn_bound(1 << 24) - churn_bound(1 << 18) == 16 * 6


# ------------------------------------------------------- encoding proofs
def _spec_864():
    spec = lin.LinearSpec.make((8, 6, 4))
    return spec, lin.reencode_spec(spec, 64)


def test_default_config_sweep_proves_clean():
    for dims, target in DEFAULT_CONFIGS:
        proof, fs = prove_encoding(dims, target_bits=target)
        assert fs == [], (dims, target, [f.message for f in fs])
        assert proof is not None
        assert proof.stored_bits <= target and proof.key_bits <= 64
        assert proof.max_coord == tuple(d - 1 for d in dims)
        assert proof.padded_lane_noop


def test_lossy_spec_rejected_and_roundtrip_actually_fails():
    """Known-bad: drop one field bit without moving it to the block key.

    The verifier must flag the broken partition, and the break is real:
    the dropped bit is stored nowhere, so the witness coordinate 5
    (binary 101) decodes to 1 under the mutilated layout.
    """
    spec, re = _spec_864()
    lossy = lin.ReencodeSpec((2,) + re.field_bits[1:], re.field_shift,
                             re.block_bits)
    fs = verify_layout((8, 6, 4), spec, lossy, symbol="lossy")
    assert any("drops or invents" in f.message for f in fs)
    witness = 5                      # bit 2 set, beyond the 2-bit field
    fb, bb = lossy.field_bits[0], lossy.block_bits[0]
    decoded = (((witness >> fb) & ((1 << bb) - 1)) << fb) \
        | (witness & ((1 << fb) - 1))
    assert decoded != witness


def test_overlapping_fields_rejected():
    spec, re = _spec_864()
    clash = lin.ReencodeSpec(re.field_bits, (0, 0, 6), re.block_bits)
    fs = verify_layout((8, 6, 4), spec, clash, symbol="clash")
    assert any("overlaps" in f.message for f in fs)


def test_mask_overflow_at_u64_boundary_rejected():
    spec, re = _spec_864()
    wrap = lin.ReencodeSpec(re.field_bits, (0, 3, 63), re.block_bits)
    fs = verify_layout((8, 6, 4), spec, wrap, symbol="wrap")
    assert any("overflows the 64-bit" in f.message for f in fs)


def test_oversized_field_and_extent_rejected():
    # bypass LinearSpec.make's guard to reach the verifier's own checks
    spec = lin.LinearSpec(dims=(1 << 33,), bits=(33,),
                          positions=(tuple(range(33)),), total_bits=33)
    re = lin.ReencodeSpec((33,), (0,), (0,))
    fs = verify_layout((1 << 33,), spec, re, symbol="huge")
    msgs = [f.message for f in fs]
    assert any("> 32" in m for m in msgs)
    assert any("2^31" in m for m in msgs)


def test_alto_bijection_violation_rejected():
    spec, re = _spec_864()
    broken = lin.LinearSpec(spec.dims, spec.bits,
                            ((0, 1, 2), (0, 4, 5), (6, 7)),  # bit 0 doubled
                            spec.total_bits)
    fs = verify_layout((8, 6, 4), broken, re, symbol="dup")
    assert any("not a bijection" in f.message for f in fs)


def test_construction_guard_is_witnessed_not_crashed():
    with pytest.raises(ValueError, match="2\\^31"):
        lin.LinearSpec.make((2**31 + 1, 4))
    proof, fs = prove_encoding((2**31 + 1, 4))
    assert proof is None
    assert len(fs) == 1 and "construction rejected" in fs[0].message


def test_int32_boundary_exactly_legal():
    proof, fs = prove_encoding((2**31, 4))
    assert fs == [] and proof is not None
    assert proof.max_coord == (2**31 - 1, 3)


# --------------------------------------------------- encoding round trips
def _roundtrip_rows(spec, re, coords):
    """Full shipped pipeline, one block at a time: encode -> key ->
    upper -> stored -> host delinearize."""
    out = np.zeros_like(coords)
    hi, lo = lin.alto_encode(spec, coords)
    keys = lin.block_key(spec, re, hi, lo)
    stored = lin.reencode(spec, re, coords)
    for i in range(coords.shape[0]):
        upper = lin.key_to_upper_coords(spec, re, int(keys[i]))
        out[i] = lin.delinearize_host(re, stored[i:i + 1], upper)[0]
    return out


def test_roundtrip_blocked_layout_host_and_device():
    """target_bits=12 forces blocking on the tests' (40,25,30) shape; the
    host oracle and the device delinearizer must both invert it."""
    dims = (40, 25, 30)
    spec = lin.LinearSpec.make(dims)
    re = lin.reencode_spec(spec, 12)
    assert verify_layout(dims, spec, re) == []
    rng = np.random.default_rng(3)
    coords = np.stack([rng.integers(0, d, 64) for d in dims], axis=1)
    # pin every extent edge — the exact coordinates check (5) reasons about
    coords[0] = [d - 1 for d in dims]
    coords[1] = 0
    assert np.array_equal(_roundtrip_rows(spec, re, coords), coords)

    # device path: same stored words through kernels.ref.delinearize_ref
    hi_a, lo_a = lin.alto_encode(spec, coords)
    keys = lin.block_key(spec, re, hi_a, lo_a)
    stored = lin.reencode(spec, re, coords)
    bases = np.stack([
        lin.key_to_upper_coords(spec, re, int(k)) <<
        np.array(re.field_bits, np.int64) for k in keys]).astype(np.int32)
    hi32, lo32 = u64.split64(stored)
    dec = delinearize_ref(jnp.asarray(hi32), jnp.asarray(lo32),
                          jnp.asarray(bases), field_bits=re.field_bits,
                          field_shifts=re.field_shift)
    assert np.array_equal(np.asarray(dec), coords)


def test_roundtrip_near_64bit_stored_word():
    """Adversarial corner: fields fill all 64 stored bits and one field
    straddles the uint32 word boundary of the (hi, lo) split."""
    dims = (2**31, 2**31, 4)
    spec = lin.LinearSpec.make(dims)
    re = lin.reencode_spec(spec, 64)
    assert sum(re.field_bits) == 64
    assert verify_layout(dims, spec, re) == []
    assert any(s < 32 < s + f
               for s, f in zip(re.field_shift, re.field_bits) if f)
    coords = np.array([[2**31 - 1, 2**31 - 1, 3],
                       [0, 0, 0],
                       [2**31 - 1, 0, 3],
                       [1, 2**31 - 1, 0],
                       [2**30, 2**30 + 1, 2]], np.int64)
    assert np.array_equal(_roundtrip_rows(spec, re, coords), coords)
    stored = lin.reencode(spec, re, coords)
    hi32, lo32 = u64.split64(stored)
    dec = delinearize_ref(jnp.asarray(hi32), jnp.asarray(lo32),
                          jnp.zeros((5, 3), jnp.int32),
                          field_bits=re.field_bits,
                          field_shifts=re.field_shift)
    assert np.array_equal(np.asarray(dec), coords)


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_property_accepts_iff_roundtrip(data):
    """Verifier accepts a shipped layout ⇔ the encoding round-trips.

    Random dims up to the int32 ceiling with random target widths: when
    the proof succeeds, every sampled coordinate (extent edges included)
    must survive encode -> block key -> stored -> delinearize bit-exactly;
    when the proof fails, the failure must name the block-key overflow
    and ``block_key`` itself must refuse the same layout.
    """
    n_modes = data.draw(st.integers(min_value=2, max_value=4))
    dims = tuple(data.draw(st.integers(min_value=1, max_value=2**31))
                 for _ in range(n_modes))
    target = data.draw(st.sampled_from((8, 16, 32, 64)))
    proof, fs = prove_encoding(dims, target_bits=target)
    spec = lin.LinearSpec.make(dims)
    re = lin.reencode_spec(spec, target)
    if proof is None:
        assert fs and all("block key" in f.message for f in fs)
        with pytest.raises(ValueError):
            lin.block_key(spec, re, np.zeros(1, np.uint64),
                          np.zeros(1, np.uint64))
        return
    rows = [tuple(d - 1 for d in dims), tuple(0 for _ in dims)]
    for _ in range(6):
        rows.append(tuple(data.draw(st.integers(0, d - 1)) for d in dims))
    coords = np.array(rows, np.int64)
    assert np.array_equal(_roundtrip_rows(spec, re, coords), coords)


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_property_broken_partition_always_flagged(data):
    """Dropping any field bit (without re-homing it) must be rejected."""
    n_modes = data.draw(st.integers(min_value=2, max_value=4))
    dims = tuple(data.draw(st.integers(min_value=2, max_value=2**20))
                 for _ in range(n_modes))
    spec = lin.LinearSpec.make(dims)
    re = lin.reencode_spec(spec, 64)
    mode = data.draw(st.integers(0, n_modes - 1))
    fields = list(re.field_bits)
    fields[mode] -= 1
    lossy = lin.ReencodeSpec(tuple(fields), re.field_shift, re.block_bits)
    fs = verify_layout(dims, spec, lossy, symbol="mutated")
    assert any(f"mode {mode}" in f.message and "drops or invents"
               in f.message for f in fs)


# ------------------------------------------------------- conflict prover
def test_fused_variants_prove_clean():
    for variant in ("segment", "stash"):
        facts, fs = prove_variant(variant)
        assert fs == [], (variant, [f.message for f in fs])
        assert any(f["primitive"] == "pallas_call" for f in facts)


def test_segment_kernel_declares_its_conflicts():
    facts, _ = prove_variant("segment")
    outer = [f for f in facts if f["primitive"] == "scatter-add"
             and not f.get("inside_pallas")]
    assert len(outer) == 1
    assert outer[0]["unique_indices"] is False


def test_stash_kernel_has_no_outer_scatter():
    facts, _ = prove_variant("stash")
    assert not any(f["primitive"].startswith("scatter")
                   for f in facts if not f.get("inside_pallas"))


def test_write_structure_fixtures_rejected():
    pallas = {"primitive": "pallas_call", "context": "<top>"}

    def scatter(unique):
        return {"primitive": "scatter-add", "unique_indices": unique,
                "inside_pallas": False, "context": "<top>"}

    # no pallas_call at all: the "fused" kernel is not fused
    fs = check_write_structure([scatter(False)], variant="segment",
                               symbol="s")
    assert any("not fused" in f.message for f in fs)
    # stash must keep ALL accumulation inside the sequential grid
    fs = check_write_structure([pallas, scatter(False)], variant="stash",
                               symbol="s")
    assert any("stash variant stages" in f.message for f in fs)
    # segment: exactly one deferred apply, and it must admit duplicates
    fs = check_write_structure([pallas, scatter(False), scatter(False)],
                               variant="segment", symbol="s")
    assert any("expected exactly one" in f.message for f in fs)
    fs = check_write_structure([pallas, scatter(True)], variant="segment",
                               symbol="s")
    assert any("unique_indices=True" in f.message for f in fs)
    assert all(f.pass_id == PASS_CONFLICT for f in fs)
    # and the real shapes pass
    assert check_write_structure([pallas, scatter(False)],
                                 variant="segment", symbol="s") == []
    assert check_write_structure([pallas], variant="stash",
                                 symbol="s") == []


def test_unique_claim_fixture_rejected():
    """Known-bad: a scatter claiming uniqueness over a duplicate-capable
    write set — the claim licenses XLA to drop conflict handling."""
    def bad(vals, idx):
        out = jnp.zeros((16,), jnp.float32)
        return out.at[idx].add(vals, unique_indices=True)

    closed = trace_jaxpr(bad, _f32(32), _i32(32))
    fs = check_scatter_claims(closed, duplicates_possible=True,
                              path="tests/fixture.py", symbol="bad")
    assert len(fs) == 1 and "unique_indices=True" in fs[0].message
    # the same claim is fine when duplicates are proven impossible
    assert check_scatter_claims(closed, duplicates_possible=False,
                                path="p", symbol="s") == []

    def fine(vals, idx):
        out = jnp.zeros((16,), jnp.float32)
        return out.at[idx].add(vals)

    assert check_scatter_claims(trace_jaxpr(fine, _f32(32), _i32(32)),
                                duplicates_possible=True,
                                path="p", symbol="s") == []


def _demo_blco():
    t = core.random_tensor((40, 25, 30), 2000, seed=1, dist="powerlaw")
    return t, core.build_blco(t, target_bits=12, max_nnz_per_block=256)


def test_conflict_report_accounting():
    t, b = _demo_blco()
    report = conflict_report(b, 0)
    assert report["dims"] == [40, 25, 30]
    assert sum(l["nnz"] for l in report["launches"]) == t.nnz
    for l in report["launches"]:
        assert l["padded_nnz"] == report["reservation"]
        assert l["tiles"] * report["tile"] == l["padded_nnz"]
        # segment count brackets: >= one per distinct row touched,
        # <= one per padded slot
        assert l["distinct_rows"] <= l["segments"] <= l["padded_nnz"]
        assert l["segments"] + l["padding_segments"] >= l["tiles"]
        if l["max_writers_per_row"] > 1:
            assert l["conflict_rows"]
    assert report["total_segments"] == sum(l["segments"]
                                           for l in report["launches"])
    json.dumps(report)


def test_segmented_reduction_invariant():
    """The acceptance-criterion invariant: the report proves the fused
    scatter's write set contains duplicate rows, the kernel's traced form
    declares exactly that (unique_indices=False), and under that conflict
    structure the segmented reduction still reproduces the oracle."""
    t, b = _demo_blco()
    report = conflict_report(b, 0)
    assert report["max_writers_per_row_per_step"] >= 2
    assert report["unique_indices_sound"] is False

    facts, _ = prove_variant("segment")
    outer = [f for f in facts if f["primitive"] == "scatter-add"
             and not f.get("inside_pallas")]
    assert outer and not outer[0]["unique_indices"], \
        "kernel claim contradicts the conflict report"

    factors = [np.random.default_rng(0).standard_normal(
        (d, 8)).astype(np.float32) for d in b.dims]
    cache = LaunchCache.from_blco(b)
    out = fused_cache_mttkrp(cache, factors, 0, resolution="register")
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    err = np.max(np.abs(np.asarray(out, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)
    assert err < 5e-4


def test_conflict_free_tensor_is_reported_sound():
    """Distinct rows, zero padding: the one case unique_indices would be
    admissible — the report must recognize it rather than cry wolf."""
    idx = np.stack([np.arange(256), np.zeros(256, np.int64),
                    np.zeros(256, np.int64)], axis=1)
    t = core.from_coo(idx, np.ones(256, np.float32), (256, 2, 2))
    b = core.build_blco(t, target_bits=64, max_nnz_per_block=1 << 20)
    report = conflict_report(b, 0)
    assert len(report["launches"]) == 1
    assert report["launches"][0]["padding_segments"] == 0
    assert report["max_writers_per_row_per_step"] == 1
    assert report["unique_indices_sound"] is True


# -------------------------------------------------------- tier integration
def test_trace_tier_clean_on_committed_tree():
    findings, bundle, m = run_trace_tier()
    assert findings == [], [f.message for f in findings]
    assert m.hot_paths_traced == 6
    assert m.encodings_verified == len(DEFAULT_CONFIGS)
    assert m.jaxpr_eqns_walked > 0 and m.launches_analyzed > 0
    assert m.findings_total == 0
    assert set(bundle) == {"conflict_report", "encoding_proofs", "metrics"}
    assert len(bundle["encoding_proofs"]) == len(DEFAULT_CONFIGS)
    json.dumps(bundle)
    assert len(TRACE_PASS_IDS) == 5


def test_lint_cli_trace_tier_and_tier_scoped_staleness(tmp_path):
    """End-to-end CLI: --tier=trace exits 0 on the committed tree, writes
    the artifact bundle, and does NOT treat an AST-tier baseline entry as
    stale when only the trace tier ran."""
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"suppressions": [{
        "pass": "dtype-promotion", "path": "src/repro/nonexistent.py",
        "symbol": "ghost", "reason": "ast-tier entry; not this tier's call",
    }]}))
    report = tmp_path / "bundle.json"
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "lint.py"),
         "--tier=trace", "--format", "json",
         "--baseline", str(baseline), "--report-out", str(report)],
        capture_output=True, text=True, cwd=REPO, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert out["findings"] == []
    assert out["stale_baseline_entries"] == []
    bundle = json.loads(report.read_text())
    assert bundle["conflict_report"]["launches"]
    assert bundle["metrics"]["hot_paths_traced"] == 6


def test_lint_cli_stale_baseline_fails_and_prunes(tmp_path):
    """A stale suppression fails the run; --prune-baseline repairs it."""
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"suppressions": [{
        "pass": "host-sync-in-hot-path", "path": "src/repro/nonexistent.py",
        "symbol": "ghost", "reason": "finding long since fixed",
    }]}))
    cmd = [sys.executable, os.path.join(REPO, "scripts", "lint.py"),
           "--tier=ast", "--baseline", str(baseline)]
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=REPO,
                          timeout=300)
    assert proc.returncode == 1
    assert "stale baseline entry" in proc.stdout
    proc = subprocess.run(cmd + ["--prune-baseline"], capture_output=True,
                          text=True, cwd=REPO, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "pruned 1 stale entry" in proc.stdout
    assert json.loads(baseline.read_text())["suppressions"] == []
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=REPO,
                          timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
