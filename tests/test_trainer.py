"""Fault-tolerance behaviours: checkpoint atomicity, resume, NaN guard."""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps
from repro.models import build_model
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt


@pytest.fixture
def tiny(tmp_path):
    cfg = get_config("minicpm_2b").reduced()
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(total_steps=30, peak_lr=1e-3, warmup=3)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, global_batch=4,
                                  seq_len=16))
    return cfg, model, opt_cfg, data, str(tmp_path / "ckpt")


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "step": np.int32(7)}
    d = str(tmp_path)
    ckpt.save(d, 5, tree)
    assert ckpt.list_steps(d) == [5]
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                                       np.asarray(x).dtype), tree)
    s, back = ckpt.restore_latest(d, like)
    assert s == 5
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])


def test_interrupted_save_never_corrupts(tmp_path):
    d = str(tmp_path)
    tree = {"x": np.ones((4,), np.float32)}
    ckpt.save(d, 1, tree)
    # simulate a crash mid-save of step 2: stray .tmp directory
    os.makedirs(os.path.join(d, "step_2.tmp"))
    like = {"x": jax.ShapeDtypeStruct((4,), np.float32)}
    s, back = ckpt.restore_latest(d, like)
    assert s == 1                        # incomplete step 2 is invisible


def test_resume_continues_training(tiny):
    cfg, model, opt_cfg, data, d = tiny
    tc = TrainerConfig(total_steps=10, ckpt_dir=d, ckpt_every=5,
                       log_every=2, ckpt_async=False)
    tr = Trainer(tc, model, opt_cfg, steps.make_train_step(cfg, opt_cfg), data)
    out1 = tr.run()
    tc2 = TrainerConfig(total_steps=16, ckpt_dir=d, ckpt_every=8,
                        log_every=2, ckpt_async=False)
    tr2 = Trainer(tc2, model, opt_cfg, steps.make_train_step(cfg, opt_cfg),
                  data)
    assert tr2.start_step == 10
    out2 = tr2.run()
    assert out2["final_step"] == 16
    assert out2["history"][-1]["loss"] < out1["history"][0]["loss"]


def test_resume_bit_exact(tiny):
    """Uninterrupted 8 steps == 4 steps + restart + 4 steps (params equal)."""
    cfg, model, opt_cfg, data, d = tiny

    tc = TrainerConfig(total_steps=8, ckpt_dir=d + "_a", ckpt_every=100,
                       ckpt_async=False)
    tr = Trainer(tc, model, opt_cfg, steps.make_train_step(cfg, opt_cfg),
                 data, init_key=jax.random.key(3))
    tr.run()
    p_straight = np.asarray(jax.device_get(
        tr.state["params"]["embed"]["table"]))

    tc1 = TrainerConfig(total_steps=4, ckpt_dir=d + "_b", ckpt_every=4,
                        ckpt_async=False)
    t1 = Trainer(tc1, model, opt_cfg, steps.make_train_step(cfg, opt_cfg),
                 data, init_key=jax.random.key(3))
    t1.run()
    tc2 = TrainerConfig(total_steps=8, ckpt_dir=d + "_b", ckpt_every=100,
                        ckpt_async=False)
    t2 = Trainer(tc2, model, opt_cfg, steps.make_train_step(cfg, opt_cfg),
                 data, init_key=jax.random.key(3))
    assert t2.start_step == 4
    t2.run()
    p_resumed = np.asarray(jax.device_get(
        t2.state["params"]["embed"]["table"]))
    np.testing.assert_allclose(p_straight, p_resumed, rtol=1e-6, atol=1e-6)


def test_nan_guard_skips_bad_batch(tiny):
    cfg, model, opt_cfg, data, d = tiny

    class PoisonData:
        def __init__(self, inner):
            self.inner = inner

        def batch_at(self, step):
            b = self.inner.batch_at(step)
            if step == 2:               # poison one batch
                b = dict(b)
                b["labels"] = np.full_like(b["labels"], 0)
                b["poison"] = None
            return b

    def poison_step(state, batch):
        nan = "poison" in batch
        batch = {k: v for k, v in batch.items() if k != "poison"}
        new_state, metrics = steps.make_train_step(cfg, opt_cfg)(state, batch)
        if nan:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(np.nan)
        return new_state, metrics

    tc = TrainerConfig(total_steps=5, ckpt_dir=d, ckpt_every=100,
                       ckpt_async=False)
    tr = Trainer(tc, model, opt_cfg, poison_step, PoisonData(data))
    out = tr.run()
    assert out["nan_skipped"] == [2]
    assert int(jax.device_get(tr.state["opt"]["step"])) == 4  # one skipped


def test_async_checkpoint_does_not_block(tiny):
    cfg, model, opt_cfg, data, d = tiny
    tc = TrainerConfig(total_steps=6, ckpt_dir=d, ckpt_every=3,
                       ckpt_async=True)
    tr = Trainer(tc, model, opt_cfg, steps.make_train_step(cfg, opt_cfg), data)
    out = tr.run()
    assert out["final_step"] == 6
    assert ckpt.list_steps(d)           # something landed on disk


def test_data_pipeline_determinism_and_sharding():
    c = DataConfig(vocab_size=100, global_batch=8, seq_len=16, seed=9)
    a = SyntheticLM(c).batch_at(5)
    b = SyntheticLM(c).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = SyntheticLM(c, host_id=0, num_hosts=2).batch_at(5)
    h1 = SyntheticLM(c, host_id=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
