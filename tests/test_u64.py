"""u64-as-2xu32 arithmetic vs Python big ints (hypothesis)."""
import numpy as np

from conftest import hypothesis_or_stub

from repro.core import u64

given, settings, st = hypothesis_or_stub()


@settings(max_examples=60, deadline=None)
@given(x=st.integers(0, 2**64 - 1),
       shift=st.integers(0, 63), width=st.integers(1, 32))
def test_extract_field(x, shift, width):
    width = min(width, 64 - shift)
    if width == 0:
        return
    hi, lo = u64.split64(np.array([x], np.uint64))
    got = int(np.asarray(u64.extract_field(hi, lo, shift, width))[0])
    assert got == (x >> shift) & ((1 << width) - 1)


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, 2**64 - 1), b=st.integers(0, 2**64 - 1))
def test_neq_and_join(a, b):
    ha, la = u64.split64(np.array([a], np.uint64))
    hb, lb = u64.split64(np.array([b], np.uint64))
    assert int(u64.join64(ha, la)[0]) == a
    got = bool(np.asarray(u64.neq64(ha, la, hb, lb))[0])
    assert got == (a != b)


@settings(max_examples=40, deadline=None)
@given(x=st.integers(0, 2**64 - 1), n=st.integers(0, 64))
def test_shift_right(x, n):
    hi, lo = u64.split64(np.array([x], np.uint64))
    nh, nl = u64.shift_right(hi, lo, n)
    got = int(u64.join64(np.asarray(nh, np.uint32), np.asarray(nl, np.uint32))[0])
    assert got == (x >> n)
